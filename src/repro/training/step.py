"""Train-step construction: loss, grads, microbatch accumulation, update.

The grad-accum loop is a ``lax.scan`` whose body contains the (data-axis)
gradient all-reduce — GSPMD then overlaps microbatch k+1's compute with
microbatch k's reduction, the standard compute/communication overlap trick.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.training import losses
from repro.training.optimizer import (OptimizerConfig, adamw_update,
                                      init_opt_state)

AUX_WEIGHT = 0.01  # MoE load-balance loss weight


def make_loss_fn(model, remat: bool = True) -> Callable:
    cfg: ModelConfig = model.cfg

    def loss_fn(params, batch):
        kwargs: Dict[str, Any] = {}
        if cfg.is_encdec:
            kwargs["frames"] = batch["frames"]
        if cfg.frontend == "vision_patches":
            kwargs["prefix_embeds"] = batch["patches"]
        hidden, aux = model.forward(params, batch["tokens"], remat=remat,
                                    return_hidden=True, **kwargs)
        if cfg.frontend == "vision_patches":
            hidden = hidden[:, batch["patches"].shape[1]:]
        ce = losses.chunked_cross_entropy(
            hidden, params["embed"], batch["labels"], batch["loss_mask"],
            logit_softcap=cfg.final_logit_softcap, unroll=cfg.cost_unroll)
        return ce + AUX_WEIGHT * aux, {"ce": ce, "aux": aux}

    return loss_fn


def make_train_step(model, opt_cfg: OptimizerConfig, *,
                    grad_accum: int = 1, remat: bool = True) -> Callable:
    loss_fn = make_loss_fn(model, remat=remat)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            (_, aux), grads = grad_fn(params, batch)
        else:
            def micro(batch_leaf):
                return batch_leaf.reshape(grad_accum,
                                          batch_leaf.shape[0] // grad_accum,
                                          *batch_leaf.shape[1:])
            micro_batch = jax.tree.map(micro, batch)

            def body(carry, mb):
                acc, _ = carry
                (_, aux), grads = grad_fn(params, mb)
                acc = jax.tree.map(jnp.add, acc, grads)
                return (acc, aux), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (grads, aux), _ = jax.lax.scan(
                body, (zeros, {"ce": jnp.float32(0), "aux": jnp.float32(0)}),
                micro_batch)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)

        new_params, new_opt, metrics = adamw_update(opt_cfg, grads,
                                                    opt_state, params)
        metrics = dict(metrics, loss=aux["ce"], moe_aux=aux["aux"])
        return new_params, new_opt, metrics

    return train_step


def init_train_state(model, key):
    params = model.init(key)
    return params, init_opt_state(params)
