"""Hardware models for roofline / cost analysis.

TPU v5e is the primary target (the mesh in launch/mesh.py is a v5e pod).
The paper's Table-1 GPUs are retained so the cross-hardware analyses of
InferBench (Fig. 7/8/10) can be reproduced against the same model set.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Peak-rate model of one accelerator chip (the roofline ceiling)."""

    name: str
    arch: str
    peak_flops: float          # FLOP/s at the serving dtype (bf16 for TPU)
    peak_flops_fp32: float     # FLOP/s at fp32
    hbm_bytes: int             # on-chip HBM capacity
    hbm_bw: float              # bytes/s HBM bandwidth
    link_bw: float             # bytes/s inter-chip interconnect per chip
    tdp_watts: float           # board power for the energy model
    cloud_usd_per_hour: Optional[float] = None  # on-demand, per chip/board

    # ---- roofline helpers -------------------------------------------------
    def ridge_intensity(self) -> float:
        """Arithmetic intensity (FLOP/byte) at the memory/compute ridge."""
        return self.peak_flops / self.hbm_bw

    def attainable_flops(self, intensity: float) -> float:
        """Roofline: attainable FLOP/s at a given arithmetic intensity."""
        return min(self.peak_flops, intensity * self.hbm_bw)


# Primary target: one TPU v5e chip (constants fixed by the assignment).
TPU_V5E = HardwareModel(
    name="tpu-v5e",
    arch="TPU v5e",
    peak_flops=197e12,          # bf16
    peak_flops_fp32=98.5e12,
    hbm_bytes=16 * 1024**3,
    hbm_bw=819e9,
    link_bw=50e9,               # per ICI link
    tdp_watts=170.0,
    cloud_usd_per_hour=1.20,    # public on-demand us-central pricing
)

# Paper Table 1 platforms (FP16 peak used as the serving dtype peak).
GPU_V100 = HardwareModel(
    name="v100", arch="GPU (Volta)", peak_flops=31.4e12,
    peak_flops_fp32=15.7e12, hbm_bytes=32 * 1024**3, hbm_bw=900e9,
    link_bw=25e9, tdp_watts=300.0, cloud_usd_per_hour=2.48)
GPU_2080TI = HardwareModel(
    name="2080ti", arch="GPU (Turing)", peak_flops=28.5e12,
    peak_flops_fp32=14.25e12, hbm_bytes=11 * 1024**3, hbm_bw=616e9,
    link_bw=8e9, tdp_watts=250.0, cloud_usd_per_hour=None)
GPU_T4 = HardwareModel(
    name="t4", arch="GPU (Turing)", peak_flops=16.2e12,
    peak_flops_fp32=8.1e12, hbm_bytes=16 * 1024**3, hbm_bw=300e9,
    link_bw=4e9, tdp_watts=70.0, cloud_usd_per_hour=0.95)
GPU_P4 = HardwareModel(
    name="p4", arch="GPU (Pascal)", peak_flops=11.0e12,
    peak_flops_fp32=5.5e12, hbm_bytes=8 * 1024**3, hbm_bw=192e9,
    link_bw=4e9, tdp_watts=75.0, cloud_usd_per_hour=0.60)
CPU_XEON = HardwareModel(
    name="cpu-xeon", arch="CPU", peak_flops=1.4e12,
    peak_flops_fp32=1.4e12, hbm_bytes=128 * 1024**3, hbm_bw=68e9,
    link_bw=1e9, tdp_watts=135.0, cloud_usd_per_hour=0.34)

HARDWARE: Dict[str, HardwareModel] = {
    h.name: h for h in (TPU_V5E, GPU_V100, GPU_2080TI, GPU_T4, GPU_P4, CPU_XEON)
}

# Energy → CO2: global-average grid intensity (kg CO2e per kWh), the same
# methodology as carbontracker used in the paper's Fig. 8.
CO2_KG_PER_KWH = 0.475

# Cloud providers offering the chip (paper Fig. 8b uses anonymized labels).
# Every HARDWARE key has at least one entry so cloud_cost_usd never falls
# into the silent-zero path for catalog hardware (2080ti has no public
# cloud SKU; the rate is a render-farm-style hourly equivalent, cpu-xeon
# mirrors its on-demand board price).
CLOUD_RATES_USD_PER_HOUR: Dict[str, Dict[str, float]] = {
    "tpu-v5e": {"C1/I1": 1.20, "C1/I2": 0.84},        # on-demand vs 1yr-commit
    "v100":    {"C1/I1": 2.48, "C2/I1": 3.06},
    "2080ti":  {"C3/I1": 0.56},
    "t4":      {"C1/I3": 0.95, "C2/I3": 0.35},
    "p4":      {"C2/I2": 0.60},
    "cpu-xeon": {"C1/I4": 0.34, "C2/I4": 0.30},
}

# Preemptible/spot pricing per chip-hour: the discount a spot pool's
# replica-seconds are billed at (in exchange for the reclamation risk the
# cluster simulator's seeded preemption process models).  Ratios follow
# typical public spot discounts (55–70% off on-demand).
SPOT_RATES_USD_PER_HOUR: Dict[str, float] = {
    "tpu-v5e": 0.48,
    "v100": 0.74,
    "2080ti": 0.20,
    "t4": 0.11,
    "p4": 0.22,
    "cpu-xeon": 0.10,
}

PRICING_CLASSES = ("reserved", "spot")


def energy_joules(hw: HardwareModel, seconds: float, util: float = 1.0) -> float:
    """Energy for a span at a given average utilization (idle draw ~30% TDP)."""
    avg_watts = hw.tdp_watts * (0.3 + 0.7 * min(max(util, 0.0), 1.0))
    return avg_watts * seconds


def co2_kg(joules: float) -> float:
    return joules / 3.6e6 * CO2_KG_PER_KWH


def cloud_rate_usd_per_hour(hw_name: str, *, instance: str | None = None,
                            pricing: str = "reserved") -> float:
    """$/chip-hour for one hardware key under a pricing class.

    ``pricing="reserved"`` (default) reads the on-demand table — the
    cheapest listed instance, or the named ``instance``.  ``"spot"``
    reads the preemptible table (falling back to 30% of on-demand for
    hardware without a listed spot rate).  Unknown hardware costs 0.0
    (self-hosted); an unknown *instance* on known hardware is a
    configuration mistake and raises.
    """
    if pricing not in PRICING_CLASSES:
        raise ValueError(f"unknown pricing class {pricing!r} "
                         f"(expected one of {PRICING_CLASSES})")
    rates = CLOUD_RATES_USD_PER_HOUR.get(hw_name, {})
    if not rates:
        return 0.0
    if instance is not None and instance not in rates:
        raise KeyError(f"no instance {instance!r} offering {hw_name!r} "
                       f"(known: {sorted(rates)})")
    rate = rates[instance] if instance else min(rates.values())
    if pricing == "spot":
        return SPOT_RATES_USD_PER_HOUR.get(hw_name, rate * 0.3)
    return rate


def cloud_cost_usd(hw_name: str, seconds: float, instance: str | None = None,
                   pricing: str = "reserved") -> float:
    rate = cloud_rate_usd_per_hour(hw_name, instance=instance,
                                   pricing=pricing)
    return rate * seconds / 3600.0
