"""Assigned architecture config (see repro.configs.catalog for the table)."""
from repro.configs.catalog import GRANITE_MOE_3B as CONFIG

__all__ = ["CONFIG"]
