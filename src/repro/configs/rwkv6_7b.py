"""Assigned architecture config (see repro.configs.catalog for the table)."""
from repro.configs.catalog import RWKV6_7B as CONFIG

__all__ = ["CONFIG"]
