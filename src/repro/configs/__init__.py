from repro.configs.catalog import ARCHS, get_config
from repro.configs.shapes import SHAPES, ShapeSpec, applicable

__all__ = ["ARCHS", "get_config", "SHAPES", "ShapeSpec", "applicable"]
