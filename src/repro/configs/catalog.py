"""The 10 assigned architectures, exact configs from the assignment table.

Each also exists as its own module (``repro.configs.<arch_id>``) exposing
``CONFIG``; this catalog is the single source of truth they import from.
"""
from __future__ import annotations

from typing import Dict

from repro.models.config import (ATTN_GLOBAL, ATTN_LOCAL, RGLRU, RWKV6,
                                 ModelConfig)

# [arXiv:2212.04356] — enc-dec, conv frontend (stub)
WHISPER_TINY = ModelConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, encoder_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    head_dim=64, d_ff=1536, vocab_size=51_865,
    frontend="audio_frames")

# [arXiv:2402.19427] — RG-LRU + local attn, 1 attention per 2 recurrent
RECURRENTGEMMA_9B = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1, head_dim=256,
    d_ff=12_288, vocab_size=256_000,
    layer_pattern=(RGLRU, RGLRU, ATTN_LOCAL), local_window=2048,
    rglru_d_rnn=4096)

# [hf:ibm-granite/granite-3.0-1b-a400m-base family] — 40 experts top-8
GRANITE_MOE_3B = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49_155, num_experts=40, experts_per_token=8)

# [hf:databricks/dbrx-base] — 16 experts top-4, fine-grained
DBRX_132B = ModelConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=10_752, vocab_size=100_352, num_experts=16, experts_per_token=4)

# [arXiv:2408.00118] — local+global alternating, logit softcap
GEMMA2_2B = ModelConfig(
    name="gemma2-2b", family="dense",
    num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4, head_dim=256,
    d_ff=9216, vocab_size=256_000,
    layer_pattern=(ATTN_LOCAL, ATTN_GLOBAL), local_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0)

# [hf:ibm-granite/granite-3.0-2b-base] — GQA
GRANITE_3_2B = ModelConfig(
    name="granite-3-2b", family="dense",
    num_layers=40, d_model=2048, num_heads=32, num_kv_heads=8, head_dim=64,
    d_ff=8192, vocab_size=49_155)

# [arXiv:2405.04324] — llama-arch, code
GRANITE_8B = ModelConfig(
    name="granite-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14_336, vocab_size=49_152)

# [arXiv:2403.04652] — llama-arch GQA
YI_9B = ModelConfig(
    name="yi-9b", family="dense",
    num_layers=48, d_model=4096, num_heads=32, num_kv_heads=4, head_dim=128,
    d_ff=11_008, vocab_size=64_000)

# [arXiv:2404.05892] — Finch, data-dependent decay, attention-free
RWKV6_7B = ModelConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64, head_dim=64,
    d_ff=14_336, vocab_size=65_536,
    layer_pattern=(RWKV6,), rwkv_head_dim=64)

# [hf:llava-hf/llava-v1.6] backbone — anyres tiling stub frontend
LLAVA_NEXT_34B = ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=20_480, vocab_size=64_000,
    frontend="vision_patches", num_frontend_tokens=2880)

ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in (
        WHISPER_TINY, RECURRENTGEMMA_9B, GRANITE_MOE_3B, DBRX_132B, GEMMA2_2B,
        GRANITE_3_2B, GRANITE_8B, YI_9B, RWKV6_7B, LLAVA_NEXT_34B)
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]
