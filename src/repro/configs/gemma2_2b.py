"""Assigned architecture config (see repro.configs.catalog for the table)."""
from repro.configs.catalog import GEMMA2_2B as CONFIG

__all__ = ["CONFIG"]
