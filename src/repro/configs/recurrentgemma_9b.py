"""Assigned architecture config (see repro.configs.catalog for the table)."""
from repro.configs.catalog import RECURRENTGEMMA_9B as CONFIG

__all__ = ["CONFIG"]
