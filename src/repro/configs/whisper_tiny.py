"""Assigned architecture config (see repro.configs.catalog for the table)."""
from repro.configs.catalog import WHISPER_TINY as CONFIG

__all__ = ["CONFIG"]
