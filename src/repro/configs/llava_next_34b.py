"""Assigned architecture config (see repro.configs.catalog for the table)."""
from repro.configs.catalog import LLAVA_NEXT_34B as CONFIG

__all__ = ["CONFIG"]
