"""Assigned input-shape set (applies to every architecture)."""
from __future__ import annotations

import dataclasses
from typing import Dict

TRAIN = "train"
PREFILL = "prefill"
DECODE = "decode"


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode
    long_context: bool = False


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, TRAIN),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, PREFILL),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, DECODE),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, DECODE, long_context=True),
}


def applicable(shape: ShapeSpec, cfg) -> bool:
    """long_500k only runs for sub-quadratic archs (SSM / hybrid)."""
    if shape.long_context:
        return cfg.sub_quadratic
    return True
