"""Assigned architecture config (see repro.configs.catalog for the table)."""
from repro.configs.catalog import DBRX_132B as CONFIG

__all__ = ["CONFIG"]
