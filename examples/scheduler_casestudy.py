"""Paper §5.5 case study: two-tier benchmark-job scheduling.

Reproduces Fig. 15 — queue-aware load balancing + SJF vs the RR+FCFS
baseline — and shows the sensitivity of the speedup to the job mix
(the paper's 1.43× sits inside the light-trace regime).

    PYTHONPATH=src python examples/scheduler_casestudy.py
"""
import numpy as np

from repro.core.scheduler import (ClusterScheduler, average_jct,
                                  evaluate_schedulers, make_job_trace)

print("Fig. 15 reproduction (4 workers, 200 jobs, mean of 5 seeds):\n")
res = {k: [] for k in ("rr_fcfs", "qa_fcfs", "rr_sjf", "qa_sjf")}
for seed in range(5):
    r = evaluate_schedulers(n_workers=4, n_jobs=200, seed=seed)
    for k in res:
        res[k].append(r[k])
for k, v in res.items():
    print(f"  {k:10s} avg JCT = {np.mean(v):7.2f}s")
print(f"\n  QA+SJF vs RR+FCFS speedup: "
      f"{np.mean(res['rr_fcfs']) / np.mean(res['qa_sjf']):.2f}x "
      f"(paper: 1.43x)\n")

print("sensitivity to the job mix (speedup vs heavy-job fraction & load):")
for heavy in (0.02, 0.05, 0.1, 0.2):
    row = []
    for rate in (0.25, 0.5, 1.0):
        sp = []
        for seed in range(6):
            jobs = make_job_trace(200, n_heavy_frac=heavy,
                                  arrival_rate=rate, seed=seed)
            rr = average_jct(ClusterScheduler(4, "rr", "fcfs").run(jobs))
            qa = average_jct(ClusterScheduler(4, "qa", "sjf").run(jobs))
            sp.append(rr / qa)
        row.append(f"{np.mean(sp):4.2f}x")
    print(f"  heavy={heavy:4.2f}:  " + "  ".join(row)
          + "   (rates 0.25 / 0.5 / 1.0 jobs/s)")
print("\nthe paper's 1.43x falls inside the light-trace band.")
