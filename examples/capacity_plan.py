"""Capacity planning from a fitted calibration profile in a few lines.

The measure → model → plan loop, end to end: calibrate a latency model
(here reusing the committed ``gemma2-2b@tpu-v5e`` profile; run
``benchmarks/bench_calibrate.py`` to regenerate it), then ask the
planner for the cheapest replicas × batching-policy × router
configuration that keeps p(e2e ≤ 250ms) ≥ 99% at the offered load.

    PYTHONPATH=src python examples/capacity_plan.py
"""
from repro.core import BenchmarkSession, PlanSpec
from repro.core.analysis import plan_table
from repro.serving.workload import WorkloadSpec

# --- declarative route: a PlanSpec through the BenchmarkSession -------------
session = BenchmarkSession(n_workers=2)
handle = session.submit(PlanSpec(
    job_id="plan-demo",
    profile="gemma2-2b@tpu-v5e",            # resolved in configs/profiles/
    workload=WorkloadSpec(kind="poisson", rate=600, duration_s=3,
                          prompt_tokens=128, output_tokens=4,
                          output_tokens_max=16, seed=0),
    slo_latency_s=0.25, slo_target=0.99,
    replicas=(1, 2, 4), policies=("tfs", "continuous"),
    routers=("round-robin", "least-loaded")))
session.run()

plan = handle.result().metrics
best = plan["best"]
print(f"profile: {plan['profile_key']}  "
      f"({plan['feasible']}/{plan['candidates']} configs meet the SLO)")
if best:
    print(f"cheapest SLO-meeting config: {best['replicas']} replica(s), "
          f"{best['policy']} batching, {best['router']} router "
          f"(${best['objective']:.5f} per 1k requests, "
          f"attainment {best['metrics']['slo_attainment']:.2f})")
else:
    print("no configuration in the grid met the SLO target")

# --- library route: the same search as one function call --------------------
from repro.calibrate import load_profile, plan_capacity  # noqa: E402

result = plan_capacity(
    load_profile("gemma2-2b@tpu-v5e"),
    WorkloadSpec(kind="poisson", rate=600, duration_s=3, prompt_tokens=128,
                 output_tokens=4, output_tokens_max=16, seed=0),
    slo_latency_s=0.25, slo_target=0.99,
    replicas=(1, 2, 4), policies=("tfs", "continuous"))
print()
print(plan_table(result))
