"""Quickstart: the paper's end-to-end workflow in ~20 lines.

Submit benchmark jobs in any of the three styles — Python objects, plain
dicts, or a config file ("a few lines of config") — let the session
schedule them across concurrent followers, and read the analysis:
leaderboard + top-3 configs under an SLO.

    PYTHONPATH=src python examples/quickstart.py
"""
from pathlib import Path

from repro.core import (BenchmarkJobSpec, BenchmarkSession,
                        ConcurrentFollowerExecutor, ModelRef)
from repro.core.analysis import leaderboard, recommend
from repro.serving.workload import WorkloadSpec

session = BenchmarkSession(n_workers=4, lb="qa", order="sjf",
                           executor=ConcurrentFollowerExecutor())

# style 1 — Python objects
handle = session.submit(BenchmarkJobSpec(
    job_id="api-job",
    model=ModelRef(name="gemma2-2b"),
    chips=8,
    slo_latency_s=0.05,
    workload=WorkloadSpec(rate=500, duration_s=5, prompt_tokens=128),
))

# style 2 — a plain dict
session.submit({
    "job_id": "dict-job",
    "model": {"name": "granite-8b"},
    "chips": 8,
    "workload": {"rate": 200, "duration_s": 5},
})

# style 3 — a config file holding a whole sweep
config = Path(__file__).resolve().parent.parent / "configs/jobs/quickstart.json"
session.submit_file(config)

records = session.run()
print(f"\nexecuted {len(records)} benchmark jobs on "
      f"{len(session.followers)} followers\n")
print(f"typed result for {handle.job_id}: "
      f"p99={handle.result().metric('p99_s')*1e3:.2f}ms "
      f"({handle.result().mode})\n")
print(leaderboard(session.db, sort_by="throughput_rps", limit=8))

print("\ntop-3 configurations under a 50 ms p99 SLO (cheapest first):")
for r in recommend(session.db, slo_latency_s=0.05):
    print(f"  {r['job_id']:16s} policy={r['policy']:5s} chips={r['chips']:3d} "
          f"p99={r['result']['p99_s']*1e3:6.2f}ms "
          f"${r['result']['cost_per_1k_req']:.4f}/1k-req")
