"""Quickstart: the paper's end-to-end workflow in ~20 lines.

Submit a benchmark sweep (a "few-lines config"), let the leader schedule it
across followers, and read the analysis: leaderboard + top-3 configs under
an SLO.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import BenchmarkJobSpec, Leader, ModelRef, SweepSpec
from repro.core.analysis import leaderboard, recommend
from repro.serving.workload import WorkloadSpec

leader = Leader(n_workers=4, lb="qa", order="sjf")

base = BenchmarkJobSpec(
    job_id="quickstart",
    model=ModelRef(name="gemma2-2b"),
    chips=8,
    slo_latency_s=0.05,
    workload=WorkloadSpec(rate=500, duration_s=5, prompt_tokens=128),
)
sweep = SweepSpec(base, axes={
    "software.policy": ["none", "tfs", "tris"],
    "chips": [4, 8, 16],
    "network": ["lan", "4g"],
})
for spec in sweep.expand():
    leader.submit(spec)

records = leader.run_all()
print(f"\nexecuted {len(records)} benchmark jobs\n")
print(leaderboard(leader.db, sort_by="throughput_rps", limit=8))

print("\ntop-3 configurations under a 50 ms p99 SLO (cheapest first):")
for r in recommend(leader.db, slo_latency_s=0.05):
    print(f"  {r['job_id']:16s} policy={r['policy']:5s} chips={r['chips']:3d} "
          f"p99={r['result']['p99_s']*1e3:6.2f}ms "
          f"${r['result']['cost_per_1k_req']:.4f}/1k-req")
