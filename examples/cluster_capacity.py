"""Cluster capacity planning in a few lines: how many replicas (and which
batching policy) does a latency SLO need at a given traffic level?

Sweeps replicas × policy over a ramped generation workload through the
declarative BenchmarkSession front end, then picks the cheapest
configuration that meets the SLO at 99% attainment.

By default the sweep is clocked by the analytic roofline model; pass a
fitted calibration profile (path or ``model@hardware`` key, see
``configs/profiles/``) to clock it by measured/fitted coefficients
instead:

    PYTHONPATH=src python examples/cluster_capacity.py
    PYTHONPATH=src python examples/cluster_capacity.py \\
        --profile gemma2-2b@tpu-v5e
"""
import argparse

from repro.core import (BenchmarkJobSpec, BenchmarkSession, ClusterSpec,
                        SweepSpec)
from repro.serving.workload import WorkloadSpec

SLO_S = 0.25

parser = argparse.ArgumentParser(description=__doc__)
parser.add_argument("--profile", default=None,
                    help="calibration profile (JSON path or model@hardware "
                         "key) to use as the latency oracle instead of the "
                         "hard-coded analytic model")
args = parser.parse_args()

base = BenchmarkJobSpec(
    job_id="capacity",
    model={"name": "gemma2-2b"},
    chips=4,
    slo_latency_s=SLO_S,
    profile=args.profile,
    software={"policy": "continuous", "max_batch": 16, "max_prefill": 8},
    cluster=ClusterSpec(replicas=1, router="least-loaded"),
    workload=WorkloadSpec(kind="ramp", duration_s=3, ramp_min_rate=50,
                          ramp_max_rate=400, ramp_steps=4,
                          output_tokens=8, output_tokens_max=32, seed=0),
)
sweep = SweepSpec(base, axes={
    "cluster.replicas": [1, 2, 4],
    "software.policy": ["tfs", "continuous"],
})

session = BenchmarkSession(n_workers=4)
session.submit_sweep(sweep)
results = session.run()

oracle = args.profile if args.profile else "analytic roofline model"
print(f"latency oracle: {oracle}\n")
print(f"{'job':14s} {'policy':11s} {'replicas':>8} {'thr rps':>9} "
      f"{'p99 ms':>8} {'SLO att':>8} {'util':>6}")
for r in sorted(results, key=lambda r: (r.spec.software.policy,
                                        r.spec.cluster.replicas)):
    m = r.metrics
    print(f"{r.job_id:14s} {r.spec.software.policy:11s} "
          f"{r.cluster['replicas']:8d} {m['throughput_rps']:9.1f} "
          f"{m['p99_s']*1e3:8.1f} {m['slo_attainment']:8.2f} "
          f"{m['utilization']:6.2f}")

best = [r for r in results if r.metric("slo_attainment") >= 0.99]
if best:
    cheapest = min(best, key=lambda r: r.metric("cost_per_1k_req"))
    print(f"\ncheapest config meeting the SLO: {cheapest.job_id} "
          f"(policy={cheapest.spec.software.policy}, "
          f"replicas={cheapest.cluster['replicas']}, "
          f"${cheapest.metric('cost_per_1k_req'):.4f}/1k req)")
else:
    print("\nno swept config met the SLO at 99% attainment")
