"""Multi-tenant serving with per-tenant SLOs in a few lines.

Two tenants share one cluster: a chat product (3/4 of the traffic,
judged by TTFT/TPOT) and a latency-critical classifier (1/4, judged by
a tight e2e SLO).  The walkthrough answers the three questions
production teams ask of a shared deployment:

  1. does each tenant meet its *own* SLOs, and how fairly is goodput
     split (Jain's index over share-normalized goodput)?
  2. does the small tenant survive the big tenant's flash burst?
  3. what is the cheapest configuration under which *every* tenant
     meets its SLOs — and does that plan hold up when the winning
     config is independently re-simulated?

    PYTHONPATH=src python examples/multi_tenant_slo.py
"""
from repro.calibrate import load_profile, plan_capacity, simulate_candidate
from repro.core.session import BenchmarkSession, resolve_policy
from repro.core.spec import SoftwareSpec
from repro.scenarios import tenant_report
from repro.scenarios.tenants import tenant_table
from repro.serving.cluster import ClusterSpec, simulate_cluster
from repro.serving.latency_model import NETWORKS, FittedLatencyModel
from repro.serving.workload import WorkloadSpec

TENANTS = ({"name": "chatbot", "share": 3.0, "scenario": "chat"},
           {"name": "classifier", "share": 1.0, "scenario": "classification"})

# --- 1. per-tenant report through the declarative session -------------------
session = BenchmarkSession(n_workers=1)
handle = session.submit({
    "job_id": "mt-demo", "model": {"name": "gemma2-2b"}, "chips": 4,
    "cluster": {"replicas": 2, "router": "least-loaded"},
    "software": {"policy": "continuous", "max_batch": 16},
    "workload": {"rate": 24, "duration_s": 6, "seed": 7,
                 "tenants": list(TENANTS)}})
session.run()
report = handle.result().metrics["tenants"]
print(tenant_table(report))

# --- 2. isolation: the big tenant bursts, the small one must survive --------
oracle = FittedLatencyModel.from_profile("gemma2-2b@tpu-v5e")
policy = resolve_policy(SoftwareSpec(policy="continuous", max_batch=16))
cluster = ClusterSpec(replicas=2, router="least-loaded")


def small_goodput(big_overrides):
    wl = WorkloadSpec(rate=24, duration_s=6, seed=7, tenants=(
        dict(TENANTS[0], workload=big_overrides), TENANTS[1]))
    res = simulate_cluster(wl, policy, oracle, cluster=cluster,
                           network=NETWORKS["lan"])
    return tenant_report(res, wl.tenants)["per_tenant"]["classifier"][
        "goodput_rps"]


steady = small_goodput({})
bursty = small_goodput({"kind": "burst", "burst_factor": 8.0})
print(f"\nclassifier goodput: steady={steady:.1f} rps, "
      f"chatbot bursting={bursty:.1f} rps "
      f"(retained {bursty / max(steady, 1e-9):.0%})")

# --- 3. cheapest config where every tenant meets its own SLOs ---------------
base = WorkloadSpec(rate=24, duration_s=4, seed=7)
plan = plan_capacity(load_profile("gemma2-2b@tpu-v5e"), base,
                     tenants=TENANTS, slo_target=0.9,
                     replicas=(1, 2, 4), policies=("continuous",))
best = plan.best
print(f"\ncheapest tenant-feasible config: {best.replicas} replica(s), "
      f"{best.policy} batching (${best.objective:.5f} per 1k requests, "
      f"fairness {best.metrics['fairness_index']:.3f})")

# trust, but verify: re-simulate the winner independently of the grid
res = simulate_candidate(load_profile("gemma2-2b@tpu-v5e"), base, best,
                         tenants=TENANTS)
verified = tenant_report(res, TENANTS)
for name, per in verified["per_tenant"].items():
    status = "ok" if per["slo_attainment"] >= 0.9 else "MISSED"
    print(f"  re-verified {name}: attainment "
          f"{per['slo_attainment']:.2f} [{status}]")
