"""Watch a flash crowd hit a cluster — the observability walkthrough.

A 2-replica cluster serves a steady 150 req/s baseline when a 10×
flash crowd lands a third of the way in.  With ``ObsSpec`` attached the
run produces all three observability artifacts:

  1. a time-series view of the incident: queue depth spiking and
     draining, batch occupancy pinning at the cap, the arrival vs.
     completion rate gap while the backlog clears;
  2. a Chrome-trace span timeline (load ``out/flash_trace.json`` at
     https://ui.perfetto.dev — one process per replica, the engine's
     iteration spans on lane 0 and per-request stage spans below);
  3. a standalone HTML report (``out/flash_report.html`` — open in any
     browser, no network access needed).

The script also shows the books balancing: the recorder's counters
reconcile exactly with the simulator's own aggregates, and the run's
summary is identical with observability on or off.

    PYTHONPATH=src python examples/observe_flash_crowd.py
"""
import dataclasses
from pathlib import Path

from repro.configs import get_config
from repro.obs import ObsSpec, write_report, write_trace
from repro.serving.batching import make_policy
from repro.serving.cluster import ClusterSpec, simulate_cluster
from repro.serving.latency_model import LatencyModel
from repro.serving.workload import WorkloadSpec

OUT = Path("out")
OUT.mkdir(exist_ok=True)

wl = WorkloadSpec(kind="flash-crowd", rate=150, duration_s=4.0,
                  burst_factor=10.0, output_tokens=16, seed=7)
cluster = ClusterSpec(replicas=2, router="least-loaded",
                      obs=ObsSpec())
lat = LatencyModel(get_config("gemma2-2b"), chips=4)

res = simulate_cluster(wl, make_policy("continuous", max_batch=8,
                                       max_prefill=4), lat,
                       cluster=cluster)

# --- 1. the incident in numbers ---------------------------------------------
ts = res.timeseries
queue = ts.total("queue_depth")
peak_i = queue.index(max(queue))
print(f"requests served        {res.requests_served or len(res.traces)}")
print(f"queue peak             {queue[peak_i]:.0f} requests "
      f"at t={ts.times[peak_i]:.2f}s")
print(f"queue at end           {queue[-1]:.0f} (drained)")
print(f"peak arrival rate      {max(ts.rate('arrivals')):.0f} req/s "
      f"(baseline {wl.rate:.0f})")
print(f"completions counter    {ts.counter_total('completions')} "
      f"(== served: books balance)")
print(f"live-replica integral  {ts.live_replica_integral():.2f}s "
      f"(== replica_seconds {res.replica_seconds:.2f}s)")

# --- 2. observability never moves a simulated number ------------------------
res_off = simulate_cluster(wl, make_policy("continuous", max_batch=8,
                                           max_prefill=4), lat,
                           cluster=dataclasses.replace(cluster, obs=None))
assert res.summary() == res_off.summary()
print("summary identical with observability off ✓")

# --- 3. artifacts ------------------------------------------------------------
trace_path = write_trace(res, OUT / "flash_trace.json",
                         title="flash crowd, 2 replicas")
print(f"span timeline          {trace_path}  (load at ui.perfetto.dev)")

rec = {"job_id": "flash-crowd-demo", "arch": "gemma2-2b",
       "hardware": "tpu-v5e", "chips": 4, "policy": "continuous",
       "result": dict(res.summary(),
                      requests_served=res.requests_served
                      or len(res.traces)),
       "timeseries": ts.to_dict()}
report_path = write_report([rec], OUT / "flash_report.html",
                           title="Flash crowd walkthrough")
print(f"HTML report            {report_path}")
