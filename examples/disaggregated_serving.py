"""Disaggregated prefill/decode serving vs colocated, judged by phase
SLOs (TTFT/TPOT).

A mixed workload — short and very long prompts, short generations — is
served two ways at the same chip count:

  1. colocated: 4 replicas, each running continuous batching end-to-end
     (long prefills pad out iterations and stall decode);
  2. disaggregated: a 3-replica chunked-prefill pool plus a 1-replica
     decode pool, with the KV cache handed off over the cluster
     interconnect (bytes = kv_bytes_per_token × prompt_tokens).

It then asks the capacity planner the deployment question directly: under
a tight TTFT+TPOT SLO, is colocated or disaggregated cheaper — and how
does that answer flip when the KV handoff must cross a slow link?

Run:  PYTHONPATH=src python examples/disaggregated_serving.py
"""
from repro.calibrate.planner import plan_capacity
from repro.configs import get_config
from repro.core.analysis import plan_table
from repro.serving.batching import make_policy
from repro.serving.cluster import ClusterSpec, DisaggSpec, simulate_cluster
from repro.serving.latency_model import LatencyModel
from repro.serving.workload import WorkloadSpec

TTFT_SLO, TPOT_SLO = 0.35, 0.03

lm = LatencyModel(get_config("gemma2-2b"), chips=4)
wl = WorkloadSpec(rate=230, duration_s=4, prompt_tokens=64,
                  prompt_tokens_max=4096, output_tokens=2,
                  output_tokens_max=8, seed=6)

configs = {
    "colocated (4 replicas)":
        ClusterSpec(replicas=4, router="least-loaded"),
    "disaggregated (3 prefill + 1 decode)":
        ClusterSpec(disaggregation=DisaggSpec(
            prefill_replicas=3, decode_replicas=1,
            prefill_chunk_tokens=512, prefill_max_batch=8)),
}

print(f"mixed workload: {wl.rate:.0f} req/s, prompts "
      f"{wl.prompt_tokens}-{wl.prompt_tokens_max} tok, outputs "
      f"{wl.output_tokens}-{wl.output_tokens_max} tok\n")
print(f"{'config':>38}{'thr rps':>9}{'ttft p99':>10}{'tpot p99':>10}"
      f"{'goodput':>9}")
for name, cluster in configs.items():
    res = simulate_cluster(
        wl, make_policy("continuous", max_batch=16, max_prefill=8), lm,
        cluster=cluster)
    print(f"{name:>38}{res.throughput():>9.1f}"
          f"{res.ttft(99) * 1e3:>8.0f}ms{res.tpot(99) * 1e3:>8.1f}ms"
          f"{res.goodput(TTFT_SLO, TPOT_SLO):>9.1f}")
    if res.pools:
        print(f"{'':>38}  (KV handoff: "
              f"{res.pools['migrated_requests']} migrations over "
              f"{res.pools['kv_network']}, mean "
              f"{res.pools['mean_kv_transfer_s'] * 1e3:.1f} ms)")

print("\n--- capacity plan under the phase SLOs "
      "(fast interconnect) ---")
plan = plan_capacity(
    lm, wl, ttft_slo_s=TTFT_SLO, tpot_slo_s=TPOT_SLO, slo_target=0.9,
    replicas=(4,), policies=("continuous",), routers=("least-loaded",),
    prefill_decode_splits=((3, 1), (2, 2)))
print(plan_table(plan))

print("\n--- lighter load, but the KV handoff crosses a slow link: "
      "transfer cost dominates and colocated wins ---")
light = WorkloadSpec(rate=140, duration_s=4, prompt_tokens=64,
                     prompt_tokens_max=4096, output_tokens=2,
                     output_tokens_max=8, seed=6)
slow = plan_capacity(
    lm, light, ttft_slo_s=TTFT_SLO, tpot_slo_s=TPOT_SLO, slo_target=0.9,
    replicas=(4,), policies=("continuous",), routers=("least-loaded",),
    prefill_decode_splits=((3, 1),), kv_network="4g")
print(plan_table(slow))
