"""End-to-end training driver: a ~100M-param granite-family model trained
for a few hundred steps on CPU with the production stack (sharded state,
AdamW, remat, data pipeline, async checkpointing, restart recovery).

    PYTHONPATH=src python examples/train_100m.py            # full (slow-ish)
    PYTHONPATH=src python examples/train_100m.py --steps 30 # quick look
"""
import sys

from repro.launch import train

if __name__ == "__main__":
    args = ["--arch", "granite-3-2b", "--reduce",
            "--steps", "200", "--batch", "8", "--seq", "256",
            "--lr", "3e-3", "--ckpt-every", "50",
            "--ckpt-dir", "/tmp/repro_100m_ckpt"]
    # allow overrides: examples/train_100m.py --steps 30
    extra = sys.argv[1:]
    for i in range(0, len(extra), 2):
        if extra[i] in args:
            j = args.index(extra[i])
            args[j + 1] = extra[i + 1]
        else:
            args += extra[i:i + 2]
    sys.argv = [sys.argv[0]] + args
    train.main()
