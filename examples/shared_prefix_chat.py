"""Memory-aware serving on a shared-prefix chat workload.

Every session reuses a long system prompt/history (its first
``prefix_tokens`` prompt tokens), so with prefix caching on, a replica
prefills that prefix once per session and serves the rest from cached KV
blocks.  This example runs the same saturating chat workload three ways:

  1. prefix caching ON  — sustains the offered rate,
  2. prefix caching OFF — prefill-bound, backs up at the same budget,
  3. a long-generation turn of the same sessions against a tight budget —
     the batcher preempts (evict + recompute) instead of over-allocating,
     and every request still completes.

Run:  PYTHONPATH=src python examples/shared_prefix_chat.py
"""
import dataclasses

from repro.core import (BenchmarkJobSpec, BenchmarkSession, ClusterSpec,
                        MemorySpec)
from repro.core.analysis import memory_table
from repro.serving.workload import WorkloadSpec

CHAT = WorkloadSpec(kind="poisson", rate=600, duration_s=3,
                    prompt_tokens=512, prefix_tokens=480,
                    output_tokens=2, output_tokens_max=4,
                    session_count=8, seed=0)
# follow-up turns: short prompts, long generations, tight KV budget
LONGGEN = dataclasses.replace(CHAT, rate=60, prompt_tokens=96,
                              prefix_tokens=64, output_tokens=128,
                              output_tokens_max=256)

CONFIGS = {
    "prefix-on": (CHAT, MemorySpec(block_tokens=16, prefix_caching=True)),
    "prefix-off": (CHAT, MemorySpec(block_tokens=16,
                                    prefix_caching=False)),
    "tight-budget": (LONGGEN, MemorySpec(block_tokens=16, hbm_gb=0.3)),
}

session = BenchmarkSession(n_workers=2)
handles = {
    name: session.submit(BenchmarkJobSpec(
        job_id=f"chat-{name}", model={"name": "gemma2-2b"}, chips=4,
        slo_latency_s=0.25,
        software={"policy": "continuous", "max_batch": 16,
                  "max_prefill": 8},
        # sticky sessions keep a session's prefix blocks on one replica
        cluster=ClusterSpec(replicas=1, router="affinity", memory=mem),
        workload=wl))
    for name, (wl, mem) in CONFIGS.items()
}
session.run()

for name, handle in handles.items():
    m = handle.result().metrics
    mem = handle.result().memory
    print(f"{name:>12}: thr={m['throughput_rps']:7.1f} rps  "
          f"p99={m['p99_s'] * 1e3:7.1f} ms  "
          f"hit={m['prefix_hit_rate']:5.1%}  "
          f"preempt={m['preemptions']:3d}  "
          f"peak_occ={m['kv_peak_occupancy']:5.1%}  "
          f"blocks={mem['total_blocks_per_replica']}")

ratio = (handles["prefix-on"].result().metrics["throughput_rps"]
         / handles["prefix-off"].result().metrics["throughput_rps"])
print(f"\nprefix caching sustains {ratio:.2f}x the cache-less throughput "
      "at the same HBM budget")
print("\n" + memory_table(session.db))
