"""Plan a heterogeneous fleet: device mix, spot pricing, multi-region.

Retires the flat-identical-replica assumption end to end.  The planner
searches fleet compositions — all-v5e, a v5e+t4 device mix, and the
same mix with the t4 pool on interruptible spot capacity — under a
``cost_per_goodput`` objective, then the winner is re-simulated
independently.  A second run places the t4 pool in another region to
show the cross-region accounting.

Uses the analytic roofline oracle directly (pools that name their own
``hardware`` re-target it per pool; a fitted profile would instead need
a per-hardware profile on each pool).

    PYTHONPATH=src python examples/mixed_fleet_plan.py
"""
from repro.calibrate import plan_capacity, simulate_candidate
from repro.configs import get_config
from repro.core.analysis import plan_table
from repro.serving.batching import make_policy
from repro.serving.cluster import ClusterSpec, PoolSpec, simulate_cluster
from repro.serving.latency_model import LatencyModel
from repro.serving.workload import WorkloadSpec

lm = LatencyModel(get_config("gemma2-2b"), chips=4)
wl = WorkloadSpec(kind="poisson", rate=120, duration_s=4,
                  prompt_tokens=128, output_tokens=8,
                  output_tokens_max=32, seed=21)
SLO_S = 0.4

# --- fleet grid: flat vs device mix vs spot-backed mix ----------------------
mixed = ({"name": "v5e", "replicas": 2},
         {"name": "t4", "hardware": "t4", "replicas": 2})
spot = ({"name": "v5e", "replicas": 2},
        {"name": "t4", "hardware": "t4", "replicas": 2,
         "pricing": "spot", "preempt_mtbf_s": 2.0})

plan = plan_capacity(
    lm, wl, slo_latency_s=SLO_S, slo_target=0.9,
    replicas=(3, 4), policies=("continuous",),
    routers=("cost-weighted",), objective="cost_per_goodput",
    fleets=(mixed, spot))
print(plan_table(plan))

best = plan.best
assert best is not None, "nothing in the grid met the SLO"
res = simulate_candidate(lm, wl, best)
print(f"\nwinner re-simulated: attainment "
      f"{res.slo_attainment(SLO_S):.2f}, bill ${res.cost_usd():.5f}")
if res.fleet is not None:
    for p in res.fleet["pools"]:
        print(f"  pool {p['name']:>4s} ({p['pricing']:>8s}): "
              f"{p['replicas']} replicas, ${p['cost_usd']:.5f}")
    print(f"  spot preemptions: {res.fleet['spot_preemptions']}, "
          f"goodput lost to kills: "
          f"{res.preemption_goodput_loss(e2e_slo_s=SLO_S):.2f} rps")

# --- multi-region: the t4 pool moves overseas -------------------------------
pools = (PoolSpec(name="v5e", replicas=2, region="us-east"),
         PoolSpec(name="t4", hardware="t4", replicas=2, region="eu-west"))
res = simulate_cluster(
    wl, make_policy("continuous", max_batch=16, max_prefill=8), lm,
    cluster=ClusterSpec(pools=pools, router="cost-weighted"))
print(f"\ntwo-region fleet: cross_region_fraction "
      f"{res.fleet['cross_region_fraction']:.2f} "
      f"(front door us-east; each hop pays one WAN RTT), "
      f"p99 {res.percentile(99) * 1e3:.0f} ms")
