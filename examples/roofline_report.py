"""Roofline report: read the dry-run artifacts and print, per cell, the
three roofline terms, the dominant bottleneck and the MFU — the Fig. 10
analysis promoted to the multi-pod engine.

    PYTHONPATH=src python examples/roofline_report.py [dryrun_v2]
"""
import json
import sys
from pathlib import Path

root = Path(__file__).resolve().parent.parent / "experiments"
which = sys.argv[1] if len(sys.argv) > 1 else "dryrun_v2"

print(f"{'arch':22s} {'shape':12s} {'dominant':11s} {'step_s':>9s} "
      f"{'compute_s':>10s} {'memory_s':>9s} {'coll_s':>9s} {'MFU':>6s}")
rows = []
for f in sorted((root / which).glob("*__single.json")):
    rec = json.loads(f.read_text())
    if rec.get("skipped") or not rec.get("ok") or "roofline" not in rec:
        continue
    r = rec["roofline"]
    rows.append((rec["arch"], rec["shape"], r))
for arch, shape, r in sorted(rows, key=lambda t: -t[2]["step_time_s"]):
    print(f"{arch:22s} {shape:12s} {r['dominant']:11s} "
          f"{r['step_time_s']:9.4f} {r['compute_s']:10.4f} "
          f"{r['memory_s']:9.4f} {r['collective_s']:9.4f} "
          f"{r['model_flops_util']:6.3f}")

doms = [r["dominant"] for _, _, r in rows]
print(f"\n{len(rows)} cells: "
      + ", ".join(f"{d}-bound: {doms.count(d)}" for d in
                  ("collective", "memory", "compute")))
print("per-cell optimized variants (rule sets): see EXPERIMENTS.md §Perf "
      "and experiments/hillclimb/")
