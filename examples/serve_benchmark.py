"""Real-execution serving benchmark: an actual jitted model behind the
dynamic batcher on CPU, comparing the three batching policies under the
same Poisson workload (the CPU-scale twin of the paper's Fig. 11d/12).

The policies and the workload are declared once as ``BenchmarkJobSpec``s
(the same spec objects a ``BenchmarkSession`` schedules) and resolved into
runnable policies via ``resolve_policy``.

    PYTHONPATH=src python examples/serve_benchmark.py
"""
from repro.configs import get_config
from repro.core import BenchmarkJobSpec, ModelRef, SweepSpec, resolve_policy
from repro.launch.serve import run_server
from repro.models import reduced
from repro.serving.workload import WorkloadSpec

cfg = reduced(get_config("gemma2-2b"))

base = BenchmarkJobSpec(
    job_id="serve-real",
    model=ModelRef(name="gemma2-2b"),
    workload=WorkloadSpec(rate=40, duration_s=4.0, prompt_tokens=32, seed=0),
)
sweep = SweepSpec(base, axes={
    "software.policy": ["none", "tfs", "tris"],
    "software.max_batch": [8],
    "software.timeout_s": [0.02],
    "software.preferred": [(8, 4, 2, 1)],
})

print(f"serving {cfg.name} (real execution, {base.workload.rate} req/s "
      "Poisson)\n")
print(f"{'policy':14s} {'requests':>9} {'thr rps':>9} {'p50 ms':>9} "
      f"{'p99 ms':>9} {'avg batch':>10}")
for spec in sweep.expand():
    policy = resolve_policy(spec.software)
    out = run_server(cfg, policy, spec.workload, max_len=64, decode_steps=4)
    print(f"{policy.name:14s} {out['requests']:9d} "
          f"{out['throughput_rps']:9.1f} {out['p50_s']*1e3:9.2f} "
          f"{out['p99_s']*1e3:9.2f} {out['mean_batch']:10.2f}")
print("\nNote the paper's finding: the TFS-style window batcher trades "
      "latency for batch size;\nthe TrIS-style eager batcher keeps p50 low "
      "at light load.")
