"""Real-execution serving benchmark: an actual jitted model behind the
dynamic batcher on CPU, comparing the three batching policies under the
same Poisson workload (the CPU-scale twin of the paper's Fig. 11d/12).

    PYTHONPATH=src python examples/serve_benchmark.py
"""
from repro.configs import get_config
from repro.launch.serve import run_server
from repro.models import reduced
from repro.serving.batching import make_policy
from repro.serving.workload import WorkloadSpec

cfg = reduced(get_config("gemma2-2b"))
wl = WorkloadSpec(rate=40, duration_s=4.0, prompt_tokens=32, seed=0)

print(f"serving {cfg.name} (real execution, {wl.rate} req/s Poisson)\n")
print(f"{'policy':14s} {'requests':>9} {'thr rps':>9} {'p50 ms':>9} "
      f"{'p99 ms':>9} {'avg batch':>10}")
for name, policy in [
        ("no-batching", make_policy("none")),
        ("tfs-window", make_policy("tfs", max_batch=8, timeout_s=0.02)),
        ("tris-preferred", make_policy("tris", preferred=(8, 4, 2, 1)))]:
    out = run_server(cfg, policy, wl, max_len=64, decode_steps=4)
    print(f"{name:14s} {out['requests']:9d} {out['throughput_rps']:9.1f} "
          f"{out['p50_s']*1e3:9.2f} {out['p99_s']*1e3:9.2f} "
          f"{out['mean_batch']:10.2f}")
print("\nNote the paper's finding: the TFS-style window batcher trades "
      "latency for batch size;\nthe TrIS-style eager batcher keeps p50 low "
      "at light load.")
