"""Generate the EXPERIMENTS.md §Dry-run/§Roofline tables from artifacts.

Baseline (v1) collective terms are post-corrected: the v1 parser counted
all-reduce at 1× result bytes; the effective-traffic model is 2× (ring),
so v1 collective bytes gain one extra all-reduce share.

    PYTHONPATH=src python experiments/make_report.py
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent
HW_PEAK, HBM_BW, LINK_BW = 197e12, 819e9, 50e9


def corrected_terms(rec, v1: bool):
    r = rec["roofline"]
    coll = r["collective_bytes_per_device"]
    if v1:
        coll += r["collectives"].get("all-reduce", {}).get("bytes", 0)
    coll_s = coll / LINK_BW
    terms = {"compute": r["compute_s"], "memory": r["memory_s"],
             "collective": coll_s}
    dom = max(terms, key=terms.get)
    step = max(terms.values())
    mfu = r["model_flops"] / (r["chips"] * HW_PEAK * step)
    return dict(r, collective_s=coll_s, dominant=dom, step_time_s=step,
                model_flops_util=mfu)


def table(dirname: str, mesh: str, v1: bool) -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | useful | MFU | peak GB/dev | compile s |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for f in sorted((ROOT / dirname).glob(f"*__{mesh}.json")):
        rec = json.loads(f.read_text())
        if rec.get("skipped"):
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"skipped (needs sub-quadratic attn) | — | — | — | — |")
            continue
        if not rec.get("ok"):
            rows.append(f"| {rec['arch']} | {rec['shape']} | FAILED ||||||||")
            continue
        if "roofline" not in rec:
            rows.append(f"| {rec['arch']} | {rec['shape']} | compile-only "
                        f"||||||| {rec.get('compile_s','—')} |")
            continue
        r = corrected_terms(rec, v1)
        peak = rec["memory"]["peak_bytes"] / 2 ** 30
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['model_flops_util']:.3f} | {peak:.2f} | "
            f"{rec.get('compile_s', 0):.1f} |")
    return "\n".join(rows)


def compile_proof_table(dirname: str) -> str:
    rows = ["| arch | shape | 16×16 | 2×16×16 | peak GB/dev (single) |",
            "|---|---|---|---|---|"]
    by_key = {}
    for f in sorted((ROOT / dirname).glob("*.json")):
        if f.stem.count("__") != 2:
            continue
        rec = json.loads(f.read_text())
        key = (rec["arch"], rec["shape"])
        by_key.setdefault(key, {})[rec["mesh"]] = rec
    for (arch, shape), recs in sorted(by_key.items()):
        s = recs.get("16x16", {})
        m = recs.get("2x16x16", {})
        def mark(r):
            if r.get("skipped"):
                return "skip"
            return "✓" if r.get("ok") else "✗"
        peak = (s.get("memory", {}).get("peak_bytes", 0) / 2 ** 30
                if s.get("ok") and not s.get("skipped") else 0)
        rows.append(f"| {arch} | {shape} | {mark(s)} | {mark(m)} | "
                    f"{peak:.2f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "proof"):
        print("### compile proof (both meshes)\n")
        print(compile_proof_table("dryrun"))
    if which in ("all", "v1"):
        print("\n### baseline roofline (single pod, paper-faithful rules)\n")
        print(table("dryrun", "single", v1=True))
    if which in ("all", "v2"):
        print("\n### optimized-defaults roofline (single pod)\n")
        print(table("dryrun_v2", "single", v1=False))
